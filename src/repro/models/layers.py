"""Shared neural layers for the architecture zoo.

Everything is functional: params are plain dicts of arrays, each `*_init`
has a matching `*_specs` returning the same tree with `Logical` leaves
(logical sharding axes, resolved by core.parallelism rules), and every
activation-entering-a-matmul passes through a `LayerQAT` site so FIXAR's
Algorithm 1 applies to any architecture (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fxp
from repro.core.parallelism import Logical, ShardingRules, constrain
from repro.core.ranges import RangeStat, finalized, update_minmax
from repro.models.config import ModelConfig

Array = jax.Array
Params = dict[str, Any]

# ---------------------------------------------------------------------------
# QAT sites for stacked-layer scans
# ---------------------------------------------------------------------------

# site names per block type (used to build the stacked (L,) range trees)
ATTN_SITES = ("attn_in", "attn_o_in", "mlp_in", "mlp_down_in")
MOE_SITES = ("attn_in", "attn_o_in", "router_in", "expert_in", "expert_down_in")
RWKV_SITES = ("tmix_in", "cmix_in")
RGLRU_SITES = ("rnn_in", "mlp_in", "mlp_down_in")
HEAD_SITES = ("head_in",)


class LayerQAT:
    """Per-layer QAT context: scalar RangeStats (sliced from the stacked
    (L,) tree by the layer scan), the traced phase flag, and the collected
    updates.  None-stats => QAT disabled (plain passthrough)."""

    def __init__(self, stats: Optional[dict[str, RangeStat]],
                 quant_phase: Optional[Array], n_bits: int = 16):
        self.stats = dict(stats) if stats is not None else None
        self.quant_phase = quant_phase
        self.n_bits = n_bits

    def site(self, name: str, x: Array) -> Array:
        if self.stats is None:
            return x
        stat = self.stats[name]
        xf = x.astype(jnp.float32)
        cand = update_minmax(stat, jax.lax.stop_gradient(xf))
        new_stat = jax.tree.map(
            lambda old, new: jnp.where(self.quant_phase, old, new), stat, cand)
        self.stats[name] = new_stat
        a_min, a_max = finalized(new_stat)
        x_q = fxp.fake_quant_affine(xf, a_min, a_max, self.n_bits)
        x_full = fxp.fake_quant(xf, fxp.FXP32)
        return jnp.where(self.quant_phase, x_q, x_full).astype(x.dtype)

    def collect(self) -> Optional[dict[str, RangeStat]]:
        return self.stats

    # -- extension points for shard_map regions (see moe.py) ----------------
    def params_for(self, name: str):
        """(a_min, a_max, quant_phase) for quantizing inside a shard_map
        body, where `site()` cannot thread the stat update itself."""
        if self.stats is None:
            return None
        a_min, a_max = finalized(self.stats[name])
        return a_min, a_max, self.quant_phase

    def fold_external(self, name: str, local_min: Array, local_max: Array):
        """Fold externally-computed (already cross-shard-reduced) min/max
        into a site's running stats (same freeze-after-delay rule)."""
        if self.stats is None:
            return
        stat = self.stats[name]
        cand = RangeStat(
            a_min=jnp.minimum(stat.a_min, local_min).astype(jnp.float32),
            a_max=jnp.maximum(stat.a_max, local_max).astype(jnp.float32),
            count=stat.count + 1)
        self.stats[name] = jax.tree.map(
            lambda old, new: jnp.where(self.quant_phase, old, new), stat, cand)


def init_site_ranges(sites: tuple[str, ...], n: int) -> dict[str, RangeStat]:
    """Stacked (n,) range tree for n layers of one pattern slot."""
    mk = lambda v: jnp.full((n,), v, jnp.float32)
    return {s: RangeStat(a_min=mk(jnp.inf), a_max=mk(-jnp.inf),
                         count=jnp.zeros((n,), jnp.int32)) for s in sites}


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig) -> Params:
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def norm_specs(cfg: ModelConfig) -> Params:
    p = {"scale": Logical("embed")}
    if cfg.norm == "layernorm":
        p["bias"] = Logical("embed")
    return p


def apply_norm(x: Array, p: Params, cfg: ModelConfig, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def group_norm_heads(x: Array, scale: Array, bias: Array, n_heads: int,
                     eps: float = 64e-5) -> Array:
    """Per-head group norm (RWKV wkv output norm). x: (..., H*hd)."""
    shape = x.shape
    xf = x.astype(jnp.float32).reshape(*shape[:-1], n_heads, -1)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.reshape(shape) * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (B, S, H, hd), positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)|(S,half)
    if ang.ndim == 2:  # (S, half) -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense helper
# ---------------------------------------------------------------------------


def _uniform_init(key, shape, fan_in):
    bound = fan_in ** -0.5
    return jax.random.uniform(key, shape, jnp.float32, -bound, bound)


# ---------------------------------------------------------------------------
# GQA attention (global / sliding-window, causal / bidirectional)
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig) -> Params:
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _uniform_init(ks[0], (d, hq, hd), d),
        "wk": _uniform_init(ks[1], (d, hk, hd), d),
        "wv": _uniform_init(ks[2], (d, hk, hd), d),
        "wo": _uniform_init(ks[3], (hq, hd, d), hq * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), jnp.float32)
        p["bk"] = jnp.zeros((hk, hd), jnp.float32)
        p["bv"] = jnp.zeros((hk, hd), jnp.float32)
    return p


def attn_specs(cfg: ModelConfig) -> Params:
    p = {
        "wq": Logical("embed", "q_heads", "head_dim"),
        "wk": Logical("embed", "kv_heads", "head_dim"),
        "wv": Logical("embed", "kv_heads", "head_dim"),
        "wo": Logical("q_heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = Logical("q_heads", "head_dim")
        p["bk"] = Logical("kv_heads", "head_dim")
        p["bv"] = Logical("kv_heads", "head_dim")
    return p


def _qkv(x, p, cfg: ModelConfig, qat: LayerQAT):
    x = qat.site("attn_in", x)
    dt = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _mask(q_pos: Array, k_pos: Array, cfg: ModelConfig, local: bool) -> Array:
    """(…, Sq, Sk) boolean mask. q_pos/k_pos: (..., S)."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                 jnp.bool_)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if cfg.causal:
        m = jnp.logical_and(m, kp <= qp)
    if local:
        m = jnp.logical_and(m, kp > qp - cfg.window)
    return m


def _sdpa(q, k, v, mask, cfg: ModelConfig, rules) -> Array:
    """Grouped scaled-dot-product attention.
    q: (B,Sq,Hq,hd), k/v: (B,Sk,Hk,hd), mask: (B,Sq,Sk) or (Sq,Sk)."""
    b, sq, hq, hd = q.shape
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, sq, hk, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, sq, hq, hd)


def _banded_local_sdpa(q, k, v, cfg: ModelConfig) -> Array:
    """Sliding-window attention over (prev, self) key chunks — O(S·2w)
    scores instead of O(S²) (§Perf-3).  Exactly equivalent to the full-score
    band mask for window w = chunk width; verified in
    tests/kernels/test_attention.py.  q: (B,S,Hq,hd), k/v: (B,S,Hk,hd)."""
    w = cfg.window
    b, s, hq, hd = q.shape
    hk = k.shape[2]
    g = hq // hk
    nc = s // w
    qc = q.reshape(b, nc, w, hk, g, hd)
    kc = k.reshape(b, nc, w, hk, hd)
    vc = v.reshape(b, nc, w, hk, hd)
    kk = jnp.concatenate(
        [jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], 1), kc], 2)
    vv = jnp.concatenate(
        [jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], 1), vc], 2)

    scores = jnp.einsum("znakgh,znmkh->znkgam", qc, kk).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    a_idx = jnp.arange(w)[:, None]
    m_idx = jnp.arange(2 * w)[None, :]
    band = jnp.logical_and(m_idx <= w + a_idx, m_idx > a_idx)
    first_ok = m_idx >= w            # chunk 0 has no previous chunk
    chunk_i = jnp.arange(nc)[:, None, None]
    mask = jnp.logical_and(band[None], jnp.logical_or(chunk_i > 0,
                                                      first_ok[None]))
    scores = jnp.where(mask[None, :, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, -1).astype(q.dtype)
    out = jnp.einsum("znkgam,znmkh->znakgh", probs, vv)
    return out.reshape(b, s, hq, hd)


def attn_forward(x: Array, p: Params, cfg: ModelConfig, *, local: bool,
                 positions: Array, rules: Optional[ShardingRules],
                 qat: LayerQAT, chunk: int = 0, unroll: bool = False,
                 cache: Optional[dict[str, Array]] = None
                 ) -> tuple[Array, Optional[dict[str, Array]]]:
    """Full-sequence attention (train / prefill). x: (B, S, d).

    `chunk` bounds the score-matrix working set by scanning query chunks;
    `unroll=True` replaces the scan with a python loop (identical math, no
    while-loop — used by the roofline harness, where cost_analysis must see
    every chunk).

    `cache` (prefill): a decode-shaped KV cache ({"k","v"}: (B, T, Hk, hd));
    the prompt's roped K / raw V are written into the exact slots
    `attn_decode` would have used (ring layout p % T for local layers,
    absolute positions for global), so decode can continue at pos = S.
    Returns (y, written_cache) — cache is None when none was passed."""
    q, k, v = _qkv(x, p, cfg, qat)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, rules, "batch", "seq", "q_heads", "head_dim")
    k = constrain(k, rules, "batch", "seq", "kv_heads", "head_dim")

    if cache is not None and positions.ndim == 1:
        s_all = x.shape[1]
        t = cache["k"].shape[1]
        keep = min(s_all, t)  # ring keeps only the last window of the prompt
        slots = positions[-keep:]
        if local and t <= cfg.window:
            slots = slots % t
        elif s_all > t:
            # absolute-slot cache: positions >= t would be silently dropped
            # by the out-of-bounds scatter and decode would read zeros
            raise ValueError(
                f"prompt length {s_all} exceeds the KV cache length {t}; "
                "init_cache with max_seq >= prompt + max_new")
        cache = {"k": cache["k"].at[:, slots].set(
                     k[:, s_all - keep:].astype(cache["k"].dtype)),
                 "v": cache["v"].at[:, slots].set(
                     v[:, s_all - keep:].astype(cache["v"].dtype))}

    s = x.shape[1]
    if local and s >= 2 * cfg.window and s % cfg.window == 0 \
            and positions.ndim == 1:
        out = _banded_local_sdpa(q, k, v, cfg)
    elif chunk and s > chunk:
        n_chunks = s // chunk
        assert s % chunk == 0

        def body(carry, qc_pc):
            qc, pc = qc_pc
            m = _mask(pc, positions, cfg, local)
            oc = _sdpa(qc, k, v, m, cfg, rules)
            return carry, oc

        qs = q.reshape(x.shape[0], n_chunks, chunk, *q.shape[2:]).swapaxes(0, 1)
        ps = positions.reshape(n_chunks, chunk) if positions.ndim == 1 else \
            positions.reshape(x.shape[0], n_chunks, chunk).swapaxes(0, 1)
        if unroll:
            outs = jnp.stack([body(None, (qs[i], ps[i]))[1]
                              for i in range(n_chunks)])
        else:
            _, outs = jax.lax.scan(body, None, (qs, ps))
        out = outs.swapaxes(0, 1).reshape(x.shape[0], s, cfg.n_heads, cfg.hd)
    else:
        m = _mask(positions, positions, cfg, local)
        out = _sdpa(q, k, v, m, cfg, rules)

    out = qat.site("attn_o_in", out.reshape(x.shape[0], s, -1))
    out = out.reshape(x.shape[0], s, cfg.n_heads, cfg.hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.compute_dtype))
    return constrain(y, rules, "batch", "seq", "embed"), cache


def attn_decode(x: Array, p: Params, cfg: ModelConfig, *, local: bool,
                cache: dict[str, Array], pos: Array,
                rules: Optional[ShardingRules], qat: LayerQAT
                ) -> tuple[Array, dict[str, Array]]:
    """One-token decode against a KV cache.

    x: (B, 1, d); cache: {"k","v"}: (B, T, Hk, hd); pos: () current index,
    or (B,) per-row indices — the continuous-batching case (serve/lm),
    where every cache lane decodes at its own position.

    Local layers use a RING cache of length `window` (§Perf-3): slot j
    holds position p_j = pos − ((pos − j) mod w), which is always inside
    the window, so the whole buffer is attended with an "is-filled" mask —
    O(w) storage and O(w) reads instead of O(S) for sliding-window layers
    (the long_500k storage win for gemma3/recurrentgemma).
    """
    q, k_new, v_new = _qkv(x, p, cfg, qat)
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.full((x.shape[0], 1), pos, jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k_new = rope(k_new, positions, cfg.rope_theta)

    t = cache["k"].shape[1]
    ring = local and t <= cfg.window
    slot = (pos % t).astype(jnp.int32) if ring else pos
    if per_row:
        # per-row scatter: lane b writes its own slot (vectorized .at[]
        # instead of dynamic_update_slice, which needs one shared index)
        rows = jnp.arange(x.shape[0])
        k_cache = cache["k"].at[rows, slot].set(k_new[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[rows, slot].set(v_new[:, 0].astype(cache["v"].dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    k_cache = constrain(k_cache, rules, "batch", "kv_seq", "kv_heads", "head_dim")
    v_cache = constrain(v_cache, rules, "batch", "kv_seq", "kv_heads", "head_dim")

    j = jnp.arange(t, dtype=jnp.int32)
    kpos = pos[:, None] if per_row else pos    # (B, 1) against j's (T,)
    if ring:
        slot_pos = kpos - (kpos - j) % t   # position stored in slot j
        valid = slot_pos >= 0              # slot filled yet?
    else:
        valid = j <= kpos
        if local:
            valid = jnp.logical_and(valid, j > kpos - cfg.window)
    # (B, Sq=1, Sk) when per-row, (1, Sq=1, Sk) broadcast otherwise
    mask = valid[:, None, :] if per_row else valid[None, None, :]

    out = _sdpa(q, k_cache, v_cache, mask, cfg, rules)
    out = qat.site("attn_o_in", out.reshape(x.shape[0], 1, -1))
    out = out.reshape(x.shape[0], 1, cfg.n_heads, cfg.hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.compute_dtype))
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLP (dense; MoE lives in moe.py)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "glu":
        return {"wg": _uniform_init(ks[0], (d, f), d),
                "wu": _uniform_init(ks[1], (d, f), d),
                "wd": _uniform_init(ks[2], (f, d), f)}
    return {"wu": _uniform_init(ks[0], (d, f), d),
            "wd": _uniform_init(ks[1], (f, d), f),
            "bu": jnp.zeros((f,), jnp.float32),
            "bd": jnp.zeros((d,), jnp.float32)}


def mlp_specs(cfg: ModelConfig) -> Params:
    if cfg.mlp_type == "glu":
        return {"wg": Logical("embed", "mlp"), "wu": Logical("embed", "mlp"),
                "wd": Logical("mlp", "embed")}
    return {"wu": Logical("embed", "mlp"), "wd": Logical("mlp", "embed"),
            "bu": Logical("mlp"), "bd": Logical("embed")}


def _act(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp_forward(x: Array, p: Params, cfg: ModelConfig,
                rules: Optional[ShardingRules], qat: LayerQAT,
                site_prefix: str = "mlp") -> Array:
    dt = cfg.compute_dtype
    x = qat.site(f"{site_prefix}_in", x)
    if cfg.mlp_type == "glu":
        h = _act(x @ p["wg"].astype(dt), cfg.act) * (x @ p["wu"].astype(dt))
    else:
        h = _act(x @ p["wu"].astype(dt) + p["bu"].astype(dt), cfg.act)
    h = constrain(h, rules, "batch", "seq", "mlp")
    h = qat.site(f"{site_prefix}_down_in", h)
    y = h @ p["wd"].astype(dt)
    if cfg.mlp_type != "glu":
        y = y + p["bd"].astype(dt)
    return constrain(y, rules, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig) -> Params:
    ke, kh = jax.random.split(key)
    p = {"embedding": jax.random.normal(ke, (cfg.vocab_size, cfg.d_model),
                                        jnp.float32) * cfg.d_model ** -0.5}
    if not cfg.tie_embeddings:
        p["head"] = _uniform_init(kh, (cfg.d_model, cfg.vocab_size), cfg.d_model)
    return p


def embed_specs(cfg: ModelConfig) -> Params:
    p = {"embedding": Logical("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["head"] = Logical("embed", "vocab")
    return p


def embed_tokens(tokens: Array, p: Params, cfg: ModelConfig,
                 rules: Optional[ShardingRules]) -> Array:
    x = p["embedding"].astype(cfg.compute_dtype)[tokens]
    x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.compute_dtype)
    return constrain(x, rules, "batch", "seq", "embed")


def lm_head(x: Array, p: Params, cfg: ModelConfig,
            rules: Optional[ShardingRules], qat: LayerQAT) -> Array:
    x = qat.site("head_in", x)
    w = (p["embedding"].T if cfg.tie_embeddings else p["head"])
    logits = x @ w.astype(cfg.compute_dtype)
    return constrain(logits, rules, "batch", "seq", "vocab")
