"""Modality frontend stubs (per task spec: [vlm]/[audio] entries are the
transformer BACKBONE only; `input_specs()` provides precomputed frame/patch
embeddings).

vision_stub (phi-3-vision): batch["frontend"] = (B, frontend_len, frontend_dim)
    CLIP patch embeddings, linearly projected into d_model and overwriting
    the first `frontend_len` token positions (prefix), labels masked there.

audio_stub (hubert): batch["frontend"] = (B, S, frontend_dim) conv-stem frame
    embeddings, projected to d_model and used *instead of* token embeddings;
    the loss is masked-frame codebook prediction.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.parallelism import Logical, ShardingRules, constrain
from repro.models.config import ModelConfig
from repro.models.layers import _uniform_init

Array = jax.Array


def frontend_init(key, cfg: ModelConfig):
    if cfg.frontend == "none":
        return {}
    return {"proj": _uniform_init(key, (cfg.frontend_dim, cfg.d_model),
                                  cfg.frontend_dim)}


def frontend_specs(cfg: ModelConfig):
    if cfg.frontend == "none":
        return {}
    return {"proj": Logical(None, "embed")}


def apply_frontend(x_embed: Array, params, batch: dict, cfg: ModelConfig,
                   rules: Optional[ShardingRules]) -> Array:
    """Merge frontend embeddings into the token-embedding sequence."""
    if cfg.frontend == "none" or "frontend" not in batch:
        return x_embed
    dt = cfg.compute_dtype
    fe = batch["frontend"].astype(dt) @ params["proj"].astype(dt)
    if cfg.frontend == "audio_stub":
        return constrain(fe, rules, "batch", "seq", "embed")
    # vision_stub: prefix replace
    flen = cfg.frontend_len
    merged = jnp.concatenate([fe[:, :flen], x_embed[:, flen:]], axis=1)
    return constrain(merged, rules, "batch", "seq", "embed")
