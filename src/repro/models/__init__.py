from repro.models import config, frontend, layers, moe, rglru, rwkv6, transformer
from repro.models.config import ALL_SHAPES, ModelConfig, ShapeConfig
