"""Mixture-of-Experts FFN (dbrx 16e/top-4, moonshot 64e/top-6).

Capacity-based GShard-style dispatch implemented with scatter/gather so the
buffers stay O(T·K·d) — compile-friendly at the 1M-token train_4k cell.
Experts are sharded over the `model` mesh axis (expert parallelism); the
expert capacity dim is sharded over `data`, which makes XLA lower the
dispatch as an all-to-all over the token shards — the production EP comm
pattern.  Expert weights additionally shard d_ff over `data` (ZeRO/FSDP
style) so dbrx-132b's optimizer state fits 512 chips (DESIGN.md §5).

QAT: per-expert activations flow through the shared layer sites
("expert_in"/"expert_down_in") — ranges are per layer, not per expert,
matching the paper's per-tensor monitoring granularity.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.parallelism import (Logical, ShardingRules, ambient_mesh,
                                    constrain)

# jax >= 0.6 promotes shard_map to the top level; older releases keep it in
# jax.experimental.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map
from repro.models.config import ModelConfig
from repro.models.layers import LayerQAT, _act, _uniform_init

Array = jax.Array
Params = dict[str, Any]


def moe_init(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _uniform_init(ks[0], (d, e), d),
        "wg": _uniform_init(ks[1], (e, d, f), d),
        "wu": _uniform_init(ks[2], (e, d, f), d),
        "wd": _uniform_init(ks[3], (e, f, d), f),
    }


def moe_specs(cfg: ModelConfig) -> Params:
    return {
        "router": Logical("embed", "experts"),
        "wg": Logical("experts", "embed", "expert_ffn"),
        "wu": Logical("experts", "embed", "expert_ffn"),
        "wd": Logical("experts", "expert_ffn", "embed"),
    }


def _blocked_cumsum(x: Array, n_blocks: int = 4096) -> Array:
    """Exclusive-friendly two-level cumsum along axis 0.

    XLA lowers a flat `jnp.cumsum` over millions of rows to a quadratic
    reduce-window (measured: 1.1e12 flops for a (262k,64) cumsum vs 8.4e7
    for this form — §Perf-1), and scanning across the token shards drags
    collectives in at every level.  Two-level scan: block-local cumsum +
    cumsum of per-block totals; block count chosen so blocks align with the
    data sharding.  Bit-identical to the flat form (integer adds).
    """
    n = x.shape[0]
    nb = n_blocks
    while n % nb != 0:
        nb //= 2
    if nb <= 1:
        return jnp.cumsum(x, axis=0)
    blocks = x.reshape(nb, n // nb, *x.shape[1:])
    local = jnp.cumsum(blocks, axis=1)
    tot = local[:, -1]
    offsets = jnp.cumsum(tot, axis=0) - tot
    return (local + offsets[:, None]).reshape(x.shape)


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.experts_per_token / cfg.n_experts
                      * cfg.moe_capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def moe_forward(x: Array, p: Params, cfg: ModelConfig,
                rules: Optional[ShardingRules], qat: LayerQAT
                ) -> tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss).  Dispatches to the shard_map
    expert-parallel path when a compatible mesh is active (§Perf-1b);
    falls back to the single-device dense-dispatch path otherwise."""
    mesh = None
    if rules is not None:
        try:
            am = ambient_mesh()
            if am is not None and not am.empty and "model" in am.axis_names:
                mesh = am
        except (ValueError, RuntimeError):
            mesh = None
    # The sharded path all-gathers the (FSDP-sharded) expert weights once
    # per layer — amortized over tokens.  Below ~64k tokens (decode shapes)
    # the dense path's scatter replication (∝ T·K·d) is cheaper than the
    # weight gather (∝ E_local·d·f), so decode stays on the dense path
    # (measured: sharded dbrx decode_32k collective 1.69 s vs 4 ms dense).
    big_enough = x.shape[0] * x.shape[1] >= 65536
    if mesh is not None and big_enough and cfg.n_experts % dict(
            zip(mesh.axis_names, mesh.axis_sizes))["model"] == 0:
        return _moe_forward_sharded(x, p, cfg, rules, qat, mesh)
    return _moe_forward_dense(x, p, cfg, rules, qat)


def _moe_forward_dense(x: Array, p: Params, cfg: ModelConfig,
                       rules: Optional[ShardingRules], qat: LayerQAT
                       ) -> tuple[Array, Array]:
    """Reference dispatch: capacity scatter/gather under auto-SPMD."""
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.n_experts
    c = capacity(t, cfg)
    dt = cfg.compute_dtype

    flat = x.reshape(t, d)
    flat = qat.site("router_in", flat)
    logits = (flat.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)         # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Switch-style load-balance aux loss
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), 0)
    density_proxy = jnp.mean(probs, 0)
    aux_loss = jnp.sum(density * density_proxy) * e

    # position of each (token, choice) within its expert buffer
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (T, K, E)
    oh_flat = onehot.reshape(t * k, e)
    pos = _blocked_cumsum(oh_flat) - oh_flat                 # exclusive
    pos_in_e = jnp.sum(pos * oh_flat, axis=-1).reshape(t, k)  # (T, K)
    keep = (pos_in_e < c).astype(dt)                         # dropped past capacity
    pos_clip = jnp.minimum(pos_in_e, c - 1)

    # scatter tokens -> (E, C, d); dropped tokens contribute zero
    contrib = flat.astype(dt)[:, None, :] * keep[..., None]  # (T, K, d)
    buf = jnp.zeros((e, c, d), dt).at[
        expert_idx.reshape(-1), pos_clip.reshape(-1)].add(
        contrib.reshape(t * k, d))
    buf = constrain(buf, rules, "experts", "exp_cap", None)

    # expert FFN, batched over E
    buf_q = qat.site("expert_in", buf)
    h = _act(jnp.einsum("ecd,edf->ecf", buf_q, p["wg"].astype(dt)), cfg.act) \
        * jnp.einsum("ecd,edf->ecf", buf_q, p["wu"].astype(dt))
    h = constrain(h, rules, "experts", "exp_cap", "expert_ffn")
    h = qat.site("expert_down_in", h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dt))
    out_buf = constrain(out_buf, rules, "experts", "exp_cap", None)

    # gather back + weighted combine
    gathered = out_buf[expert_idx.reshape(-1), pos_clip.reshape(-1)]
    gathered = gathered.reshape(t, k, d) * keep[..., None]
    y = jnp.sum(gathered * gate_vals.astype(dt)[..., None], axis=1)
    y = y.reshape(b, s, d)
    return constrain(y, rules, "batch", "seq", "embed"), aux_loss


# ---------------------------------------------------------------------------
# shard_map expert-parallel path (§Perf-1b)
# ---------------------------------------------------------------------------
#
# The auto-SPMD scatter/gather dispatch replicates the (T·K, d) update tensor
# (all-gather) and all-reduces the scattered buffer — measured 33 TB + 35 TB
# per device per step on dbrx train_4k (results/roofline/baseline).  The
# explicit formulation exploits the mesh structure instead:
#
#   * activations are sharded over (pod,)data and REPLICATED over model, so
#     every model-column device can locally select the tokens routed to its
#     own experts — dispatch costs ZERO collective bytes;
#   * per-data-shard capacity (GShard "groups" semantics) keeps dispatch
#     positions shard-local (local blocked cumsum);
#   * expert weights arrive (E/m, d, f/nd) (EP over model × ZeRO over data)
#     and are all-gathered over data per layer — the standard FSDP cost;
#   * the combine is one psum over model of the (T_local, d) partial
#     outputs — the inherent EP combine traffic.
#
# Projected per-device collective bytes for dbrx train_4k: ~0.2 TB vs 69 TB
# baseline; measured numbers in EXPERIMENTS.md §Perf-1.


def _moe_forward_sharded(x: Array, p: Params, cfg: ModelConfig,
                         rules: ShardingRules, qat: LayerQAT, mesh
                         ) -> tuple[Array, Array]:
    from jax.sharding import PartitionSpec as P

    axis_sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    batch_axes = ("pod", "data") if "pod" in axis_sizes else ("data",)
    all_axes = tuple(mesh.axis_names)
    n_model = axis_sizes["model"]
    e, k = cfg.n_experts, cfg.experts_per_token
    e_local = e // n_model
    dt = cfg.compute_dtype
    b, s, d = x.shape

    # QAT: router/expert input sites hoisted onto the (replicated-over-model)
    # token stream — same tensor content as the dispatched buffer.
    x = qat.site("router_in", x)
    x = qat.site("expert_in", x)
    hidden_qat = qat.params_for("expert_down_in")
    use_qat = hidden_qat is not None
    if not use_qat:  # dummy operands keep the shard_map signature static
        hidden_qat = (jnp.float32(-1), jnp.float32(1), jnp.array(False))

    t_global = b * s
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= axis_sizes[a]
    c_local = capacity(t_global // n_batch_shards, cfg)

    def body(xl, router, wg, wu, wd, qat_in):
        # xl: (B_l, S, d); router: (d, E); w*: (E_l, d, f_l)
        tl = xl.shape[0] * xl.shape[1]
        flat = xl.reshape(tl, d)
        logits = (flat.astype(jnp.float32) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        # aux load-balance loss (identical across model by construction)
        density = jnp.mean(
            jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), 0)
        density_proxy = jnp.mean(probs, 0)
        aux = jnp.sum(density * density_proxy) * e
        aux = jax.lax.pmean(aux, batch_axes)

        # ---- local dispatch (no collectives) ------------------------------
        onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)
        oh_flat = onehot.reshape(tl * k, e)
        pos = _blocked_cumsum(oh_flat, n_blocks=256) - oh_flat
        pos_in_e = jnp.sum(pos * oh_flat, -1).reshape(tl, k)
        keep = (pos_in_e < c_local).astype(dt)
        pos_clip = jnp.minimum(pos_in_e, c_local - 1)

        my_e_lo = jax.lax.axis_index("model") * e_local
        rel_e = expert_idx - my_e_lo
        mine = jnp.logical_and(rel_e >= 0, rel_e < e_local)
        contrib = flat.astype(dt)[:, None, :] * (keep * mine.astype(dt))[..., None]
        rel_clip = jnp.clip(rel_e, 0, e_local - 1)
        buf = jnp.zeros((e_local, c_local, d), dt).at[
            rel_clip.reshape(-1), pos_clip.reshape(-1)].add(
            contrib.reshape(tl * k, d))

        # ---- expert FFN (weights FSDP-gathered over data) ------------------
        wg_full = jax.lax.all_gather(wg, "data", axis=2, tiled=True).astype(dt)
        wu_full = jax.lax.all_gather(wu, "data", axis=2, tiled=True).astype(dt)
        wd_full = jax.lax.all_gather(wd, "data", axis=1, tiled=True).astype(dt)
        h = _act(jnp.einsum("ecd,edf->ecf", buf, wg_full), cfg.act) \
            * jnp.einsum("ecd,edf->ecf", buf, wu_full)

        if use_qat:
            a_min, a_max, quant_phase = qat_in
            from repro.core import fixedpoint as fxp
            h32 = h.astype(jnp.float32)
            h_q = fxp.fake_quant_affine(h32, a_min, a_max, cfg.qat_bits)
            h_full = fxp.fake_quant(h32, fxp.FXP32)
            h = jnp.where(quant_phase, h_q, h_full).astype(dt)
            hsg = jax.lax.stop_gradient(h32)
            h_min = jax.lax.pmin(hsg.min(), all_axes)
            h_max = jax.lax.pmax(hsg.max(), all_axes)
        else:
            h_min = h_max = jnp.float32(0)

        out_buf = jnp.einsum("ecf,efd->ecd", h, wd_full)

        # ---- combine: gather my experts' outputs, psum over model ---------
        gathered = out_buf[rel_clip.reshape(-1), pos_clip.reshape(-1)]
        gathered = gathered.reshape(tl, k, d) \
            * (keep * mine.astype(dt))[..., None]
        y = jnp.sum(gathered * gate_vals.astype(dt)[..., None], 1)
        y = jax.lax.psum(y, "model")
        return y.reshape(xl.shape), aux, h_min, h_max

    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None, None)
    y, aux, h_min, h_max = _shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(None, None), P("model", None, "data"),
                  P("model", None, "data"), P("model", "data", None),
                  (P(), P(), P())),
        out_specs=(bspec, P(), P(), P()),
    )(x.astype(dt), p["router"].astype(jnp.float32), p["wg"], p["wu"],
      p["wd"], hidden_qat)
    if use_qat:
        qat.fold_external("expert_down_in", h_min, h_max)
    return y, aux
